"""Shared helpers for the paper-figure benchmarks.

Scaling note (EXPERIMENTS.md): the container has 2 CPU cores, so the
benchmarks default to the LIGHT CNN (same V=5 structure, ~30x fewer FLOPs)
and reduced rounds. Set REPRO_BENCH_FULL=1 for paper-scale settings.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

import numpy as np

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))


def fed_setup(dataset: str = "mnist", n: int = 2400, n_clients: int = 10,
              seed: int = 0, alpha: Optional[float] = None):
    from repro.data import dirichlet_partition, iid_partition, make_image_dataset
    from repro.data.federated import rho_weights

    ds = make_image_dataset(dataset, n=n, seed=seed)
    train, test = ds.split(0.9, seed=seed)
    if alpha is None:
        parts = iid_partition(len(train.x), n_clients, seed=seed)
    else:
        parts = dirichlet_partition(train.y, n_clients, alpha=alpha, seed=seed)
    return train, test, parts, rho_weights(parts)


def run_scheme(scheme: str, cut: int, rounds: int, dataset: str = "mnist",
               n_clients: int = 10, batch: int = 16, tau: int = 1,
               lr: float = 0.05, eval_every: int = 20, seed: int = 0,
               uplink_codec: str = "fp32", downlink_codec: str = "fp32",
               cohort: Optional[int] = None,
               sampler: str = "uniform") -> Dict:
    """Train one scheme; returns accuracy curve + comm accounting.
    ``cohort``/``sampler`` opt into partial participation (K of
    n_clients per round, DESIGN.md §13); default is everyone."""
    from repro.configs.paper_cnn import LIGHT_CONFIG
    from repro.core.simulator import FedSimulator, SimConfig
    from repro.data.federated import round_batches

    train, test, parts, rho = fed_setup(dataset, n_clients=n_clients, seed=seed)
    sim = FedSimulator(LIGHT_CONFIG,
                       SimConfig(scheme=scheme, cut=cut, n_clients=n_clients,
                                 batch=batch, tau=tau, lr=lr,
                                 uplink_codec=uplink_codec,
                                 downlink_codec=downlink_codec,
                                 cohort=cohort,
                                 sampler=sampler if cohort else "full",
                                 cohort_seed=seed),
                       rho=rho, seed=seed)
    rng = np.random.RandomState(seed)
    accs, rounds_axis, losses, drifts = [], [], [], []
    for r in range(rounds):
        idx, _ = sim.cohort_for_round(sim._t)
        xs, ys = round_batches(train, parts, batch, tau, rng, idx=idx)
        m = sim.run_round(xs, ys)
        losses.append(m["loss"])
        drifts.append(m["client_drift"])
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            accs.append(sim.evaluate(test.x, test.y))
            rounds_axis.append(r + 1)
    cb = sim.comm_bytes_per_round()
    # plain-SGD training oscillates; report the mean of the last few evals
    tail = accs[-3:] if len(accs) >= 3 else accs
    return {"scheme": scheme, "cut": cut, "accs": accs, "rounds": rounds_axis,
            "losses": losses, "drifts": drifts, "comm": cb,
            "comm_bits": sim.comm_bits_per_round(),
            "final_acc": float(np.mean(tail))}


def rounds_to_acc(result: Dict, target: float) -> Optional[int]:
    for r, a in zip(result["rounds"], result["accs"]):
        if a >= target:
            return r
    return None


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
