"""Fig. 9 (extension) — accuracy vs cut-layer bit-width.

Sweeps the transport codec on SFL-GA's uplink+downlink at a fixed cut and
reports final accuracy against per-round traffic. The claim under test:
int8 (≈3.9x smaller payloads) matches fp32 accuracy within noise, int4
costs a little accuracy for ≈7.8x, and the codec saving multiplies the
scheme-level saving of Fig. 4 (aggregation-broadcast vs unicast).
"""
from __future__ import annotations

from benchmarks.common import FULL, run_scheme

from repro import obs

CODECS = ("fp32", "bf16", "fp8", "int8", "int4")


def run(dataset: str = "mnist", rounds: int = None, cut: int = 2):
    rounds = rounds or (150 if FULL else 60)
    out = []
    base_bits = None
    for codec in CODECS:
        r = run_scheme("sfl_ga", cut, rounds, dataset,
                       uplink_codec=codec, downlink_codec=codec)
        bits = r["comm_bits"]["total_bits"]
        if base_bits is None:
            base_bits = bits
        out.append({"codec": codec, "final_acc": r["final_acc"],
                    "kb_per_round": bits / 8e3,
                    "ratio_vs_fp32": base_bits / bits,
                    "curve": list(zip(r["rounds"], r["accs"]))})
    return out


def main():
    datasets = ["mnist", "fmnist"] if FULL else ["mnist"]
    for ds in datasets:
        obs.log(f"# fig9 dataset={ds} (sfl_ga, cut=2)")
        for row in run(ds):
            obs.log(f"  {row['codec']:>5}: final_acc={row['final_acc']:.3f} "
                  f"{row['kb_per_round']:8.1f} kB/round "
                  f"({row['ratio_vs_fp32']:.2f}x vs fp32)")


if __name__ == "__main__":
    main()
