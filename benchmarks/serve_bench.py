"""Serving benchmark: continuous batching vs the fixed-batch baseline
(DESIGN.md §18, ROADMAP item 4).

One ``ServeEngine`` per scheduling policy, identical model / slots /
paged cache / codec / request stream: the baseline is the engine with
``backfill=False`` (slots fill together and the batch runs to full
drain), so the comparison isolates the SCHEDULER — kernels and caches
are shared. The request stream is deliberately heavy-tailed (generation
lengths cycle ``[48, 3, 3, 2]``): under a drain barrier every batch
runs at the pace of its 48-token straggler while three slots idle,
which is exactly the regime continuous batching exists for. The
acceptance gate is ≥ 2× aggregate decode tok/s at equal slot count
with more queued users than slots.

Sweeps concurrent users vs p50/p99 per-token latency (measured step
wall-clock + modeled per-user comm latency, ``slo_ms`` attainment) and
verifies the per-step decode/prefill traffic ledger reconciles exactly
against ``sysmodel.traffic`` over the whole run — the serving analogue
of the fig12 async reconciliation gate.

Run directly:  PYTHONPATH=src python benchmarks/serve_bench.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SLOTS = 4
PROMPT_LEN = 16
GEN_PATTERN = (48, 3, 3, 2)   # heavy tail: one straggler per 4 users
MAX_LEN = PROMPT_LEN + max(GEN_PATTERN)
PAGE_SIZE = 16
CODEC = "int8"
SLO_MS = 200.0
USER_SWEEP = (4, 8, 16)
WARMUP_USERS = 4


def _measure(engine, reqs):
    """Run ``reqs`` to completion on ``engine``; stats over THIS segment
    only (earlier warmup/segments excluded)."""
    from repro import obs

    l0 = len(engine.step_latencies_s)
    c0 = len(engine.completions)
    for r in reqs:
        engine.submit(r)
    engine.run()
    comps = engine.completions[c0:]
    wall = sum(engine.step_latencies_s[l0:])
    tokens = sum(c.num_tokens for c in comps)
    lat = [t for c in comps for t in c.token_latencies_s]
    slo_tokens = sum(len(c.token_latencies_s) for c in comps)
    hits = sum(c.slo_hits for c in comps)
    return {
        "users": len(comps),
        "tokens": tokens,
        "steps": len(engine.step_latencies_s) - l0,
        "wall_s": wall,
        "tok_per_s": tokens / max(wall, 1e-9),
        "p50_s": obs.percentile(lat, 0.50),
        "p99_s": obs.percentile(lat, 0.99),
        "slo_attainment": hits / max(slo_tokens, 1),
    }


def run():
    import jax
    import jax.numpy as jnp

    from repro import obs
    from repro.configs import get_config, reduced_config
    from repro.core.serve_engine import ServeEngine, make_requests
    from repro.models import lm
    from repro.obs.ledger import reconcile_events

    cfg = reduced_config(get_config("granite-8b"))
    plan = lm.build_plan(cfg, 1)
    params = lm.init_lm(jax.random.key(0), plan, jnp.float32)

    def build(backfill: bool) -> ServeEngine:
        return ServeEngine(params, plan, slots=SLOTS, max_len=MAX_LEN,
                           page_size=PAGE_SIZE, codec=CODEC,
                           backfill=backfill, slo_ms=SLO_MS, seed=0)

    def warm(engine) -> None:
        # absorb jit compilation (prefill at PROMPT_LEN + the decode
        # step) so the tok/s segments time steady-state dispatches
        _measure(engine, make_requests(WARMUP_USERS, PROMPT_LEN, 2,
                                       vocab_size=cfg.vocab_size, seed=99))

    rec = obs.Recorder(None)
    rows = []
    with obs.use_recorder(rec):
        cont = build(backfill=True)
        warm(cont)
        for users in USER_SWEEP:
            reqs = make_requests(users, PROMPT_LEN, GEN_PATTERN,
                                 vocab_size=cfg.vocab_size, seed=1)
            rows.append({"scheduler": "continuous", "slots": SLOTS,
                         **_measure(cont, reqs)})
        seq = build(backfill=False)
        warm(seq)
        users = max(USER_SWEEP)
        reqs = make_requests(users, PROMPT_LEN, GEN_PATTERN,
                             vocab_size=cfg.vocab_size, seed=1)
        rows.append({"scheduler": "sequential", "slots": SLOTS,
                     **_measure(seq, reqs)})

    _, bad = reconcile_events(rec.events)
    n_traffic = sum(1 for e in rec.events if e.get("kind") == "traffic")
    cont_row = next(r for r in rows
                    if r["scheduler"] == "continuous"
                    and r["users"] == max(USER_SWEEP))
    seq_row = next(r for r in rows if r["scheduler"] == "sequential")
    return {
        "rows": rows,
        "speedup": cont_row["tok_per_s"] / max(seq_row["tok_per_s"], 1e-9),
        "traffic_events": n_traffic,
        "traffic_mismatches": bad,
    }


def main():
    out = run()
    print("scheduler,users,slots,tokens,steps,tok_per_s,p50_ms,p99_ms,slo")
    for r in out["rows"]:
        print(f"{r['scheduler']},{r['users']},{r['slots']},{r['tokens']},"
              f"{r['steps']},{r['tok_per_s']:.1f},{r['p50_s'] * 1e3:.1f},"
              f"{r['p99_s'] * 1e3:.1f},{r['slo_attainment']:.3f}")
    print(f"# continuous vs sequential speedup: {out['speedup']:.2f}x  "
          f"traffic events {out['traffic_events']} "
          f"mismatches {out['traffic_mismatches']}")


if __name__ == "__main__":
    main()
