"""Fig. 5 — accuracy vs wall-clock latency across schemes.

Latency per round comes from the wireless system model (eq. 29) with
optimal resource allocation (P2.1). FL pays full-model on-device compute
(the paper's point: it is slowest to converge in wall-clock).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, run_scheme

from repro import obs


def _round_latency(scheme: str, cut: int, seed: int = 0) -> float:
    """Expected per-round latency under the paper's §V-A system constants."""
    from repro.ccc.convex import solve_p21
    from repro.configs.paper_cnn import LIGHT_CONFIG
    from repro.models import cnn
    from repro.sysmodel.comm import CommParams, path_loss_gain
    from repro.sysmodel.comp import CompParams

    rng = np.random.RandomState(seed)
    gains = path_loss_gain(rng.uniform(0.05, 0.5, 10), rng)
    comm, comp = CommParams(), CompParams()
    batch = 16
    if scheme == "fl":
        # full model on client CPU + model exchange, no split
        w = (comp.client_fwd_flops + comp.client_bwd_flops
             + comp.server_fwd_flops + comp.server_bwd_flops)
        t_comp = batch * w / comp.client_cpu_max
        q_bits = cnn.total_params(LIGHT_CONFIG) * 32
        from repro.sysmodel.comm import downlink_rate, uplink_rate

        bw = np.full(10, comm.total_bandwidth / 10)
        r_up = uplink_rate(bw, np.full(10, comm.client_power), gains, comm)
        t_up = float(np.max(q_bits / r_up))
        t_dn = float(np.max(q_bits / downlink_rate(gains, comm)))
        return t_comp + t_up + t_dn
    X_bits = cnn.smashed_numel(LIGHT_CONFIG, cut) * batch * 32
    r = solve_p21(gains, X_bits, batch, comm, comp)
    lat = r.total
    if scheme == "sfl":  # client-model aggregation round-trips
        from repro.sysmodel.comm import downlink_rate, uplink_rate

        phi_bits = cnn.phi(LIGHT_CONFIG, cut) * 32
        bw = np.full(10, comm.total_bandwidth / 10)
        r_up = uplink_rate(bw, np.full(10, comm.client_power), gains, comm)
        lat += float(np.max(phi_bits / r_up)) \
            + float(np.max(phi_bits / downlink_rate(gains, comm)))
    return lat


def run(dataset: str = "mnist", rounds: int = None):
    rounds = rounds or (150 if FULL else 60)
    out = []
    for scheme in ("sfl_ga", "sfl", "psl", "fl"):
        r = run_scheme(scheme, 2, rounds, dataset)
        lat = _round_latency(scheme, 2)
        out.append({"scheme": scheme, "latency_per_round_s": lat,
                    "final_acc": r["final_acc"],
                    "time_acc_curve": [(lat * rr, a) for rr, a in
                                       zip(r["rounds"], r["accs"])]})
    return out


def main():
    obs.log("# fig5 accuracy vs latency (mnist)")
    for row in run():
        obs.log(f"  {row['scheme']}: {row['latency_per_round_s']:.3f} s/round, "
              f"final_acc={row['final_acc']:.3f}, "
              f"time_to_final={row['time_acc_curve'][-1][0]:.1f}s")


if __name__ == "__main__":
    main()
