"""Fig. 3 — convergence vs cutting point.

Paper claim: SFL (benchmark) converges fastest; SFL-GA degrades as the
cutting point v grows (bigger client model => bigger aggregation
discrepancy Γ(φ(v))). We sweep v ∈ {1..4} for SFL-GA + the SFL reference
and report accuracy after R rounds plus the measured client drift
(the Γ proxy of Assumption 4).
"""
from __future__ import annotations

from benchmarks.common import FULL, run_scheme

from repro import obs


def run(dataset: str = "mnist", rounds: int = None):
    rounds = rounds or (150 if FULL else 60)
    out = []
    for cut in (1, 2, 3, 4):
        r = run_scheme("sfl_ga", cut, rounds, dataset)
        out.append({"scheme": f"sfl_ga_v{cut}", "final_acc": r["final_acc"],
                    "drift": r["drifts"][-1], "curve": list(zip(r["rounds"],
                                                                r["accs"]))})
    ref = run_scheme("sfl", 2, rounds, dataset)
    out.append({"scheme": "sfl_ref", "final_acc": ref["final_acc"],
                "drift": 0.0, "curve": list(zip(ref["rounds"], ref["accs"]))})
    return out


def main():
    datasets = ["mnist", "fmnist", "cifar10"] if FULL else ["mnist"]
    for ds in datasets:
        obs.log(f"# fig3 dataset={ds}")
        for row in run(ds):
            obs.log(f"  {row['scheme']}: final_acc={row['final_acc']:.3f} "
                  f"drift={row['drift']:.3e}")


if __name__ == "__main__":
    main()
