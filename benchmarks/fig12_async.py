"""Fig. 12 (extension) — buffered-async vs the global barrier:
accuracy per unit of virtual wall-clock under stragglers.

The paper's latency model (§IV eq. 29) prices per-client completion
times χ+ψ, but both its stacks still run every round as a global
barrier: the round costs the SLOWEST client's completion. The
event-driven engine (DESIGN.md §16, ``core.async_engine``) merges the
B earliest completions instead, staleness-discounting late deltas —
so under a heterogeneous fleet the model keeps moving while stragglers
finish. This benchmark runs both loops per scheme (sfl_ga / psl / sfl)
over the SAME heterogeneous completion draw
(``sysmodel.latency.completion_time_fn``, slowest/fastest ≥ 4×) and
reports:

* the (virtual wall-clock, accuracy) curve of each loop — the sync
  barrier charges max over the cohort per round, the async engine's
  clock advances event by event;
* accuracy at the matched wall-clock budget (the shorter run's final
  clock) — the headline: async ≥ sync at equal virtual time under
  stragglers;
* exact traffic reconciliation for BOTH loops: every obs ``traffic``
  event's measured ledger must equal the ``sysmodel/traffic`` model
  bit for bit (the async split prices compute legs at dispatch size
  and the model-sync uplink at merge size).

Run:  PYTHONPATH=src:. python benchmarks/fig12_async.py [--fast]
          [--buffer B] [--straggler X]
Fast mode (CI): N=24, K=6, B=2, 6 sync rounds per scheme.
"""
from __future__ import annotations

import argparse
import warnings
from typing import Dict, List

import numpy as np

from benchmarks.common import FULL
from repro import obs

CUT = 1
BATCH = 8
SCHEMES = ("sfl_ga", "psl", "sfl")


def _acc_at(curve, budget_s: float) -> float:
    """Step interpolation: last accuracy reached within the budget."""
    acc = 0.0
    for t, a in curve:
        if t <= budget_s:
            acc = a
    return acc


def _check_traffic(events) -> Dict[str, int]:
    ok = bad = 0
    for e in events:
        if e.get("kind") != "traffic":
            continue
        meas, mod = e["measured"], e["modeled"]
        cats = [c for c in meas if c in mod]
        if cats and all(int(meas[c]) == int(mod[c]) for c in cats):
            ok += 1
        else:
            bad += 1
    return {"ok": ok, "bad": bad}


def run_one(scheme: str, *, n_clients: int, cohort: int, buffer: int,
            rounds: int, n_samples: int, straggler: float = 8.0,
            eval_every: int = 2, seed: int = 0) -> Dict:
    from repro.configs.paper_cnn import LIGHT_CONFIG
    from repro.core.protocol import round_seed
    from repro.core.simulator import FedSimulator, SimConfig
    from repro.data import iid_partition, make_image_dataset
    from repro.data.federated import round_batches
    from repro.sysmodel.latency import completion_time_fn

    ds = make_image_dataset("mnist", n=n_samples, seed=seed)
    train, test = ds.split(0.9)
    parts = iid_partition(len(train.x), n_clients, seed=seed)
    completion = completion_time_fn(n_clients, seed=seed,
                                    straggler_factor=straggler, batch=BATCH)

    def make_sim(rec):
        with obs.use_recorder(rec):
            return FedSimulator(
                LIGHT_CONFIG,
                SimConfig(scheme=scheme, cut=CUT, n_clients=n_clients,
                          batch=BATCH, cohort=cohort, sampler="uniform",
                          cohort_seed=seed),
                seed=seed)

    # -- sync barrier: each round waits for its slowest participant ----
    rec_s = obs.Recorder()
    sim = make_sim(rec_s)
    rng = np.random.RandomState(seed)
    clock, sync_curve = 0.0, []
    with obs.use_recorder(rec_s), warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for t in range(rounds):
            rec_s.set_round(t)
            idx, _ = sim.cohort_for_round(sim._t)
            xs, ys = round_batches(train, parts, BATCH, 1, rng, idx=idx)
            sim.run_round(xs, ys)
            clock += float(np.asarray(completion(t))[idx].max())
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                sync_curve.append((clock, sim.evaluate(test.x, test.y)))
    sim.close()
    sync_recon = _check_traffic(rec_s.events)

    # -- buffered async: same completion draw, merge B earliest -------
    rec_a = obs.Recorder()
    sim = make_sim(rec_a)

    def data_fn(d, idx):
        rng_d = np.random.RandomState(int(round_seed(seed, d)) % (2**31 - 1))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            return round_batches(train, parts, BATCH, 1, rng_d,
                                 idx=np.asarray(idx))

    with obs.use_recorder(rec_a):
        eng = sim.async_engine(data_fn, buffer=buffer,
                               completion_fn=completion)
        async_curve, merges = [], 0
        # equal virtual-time budget: run merges until the sync clock
        while eng.clock < clock:
            eng.step()
            merges += 1
            if merges % eval_every == 0:
                async_curve.append((eng.clock,
                                    sim.evaluate(test.x, test.y)))
        for _ in eng.drain():
            pass
        async_curve.append((eng.clock, sim.evaluate(test.x, test.y)))
    st = eng.stats()
    sim.close()
    async_recon = _check_traffic(rec_a.events)
    stale = [float(e["staleness_mean"]) for e in rec_a.events
             if e.get("kind") == "async" and e.get("name") == "merge"]

    budget = min(clock, async_curve[-1][0])
    return {
        "scheme": scheme,
        "sync_clock_s": clock,
        "async_clock_s": async_curve[-1][0],
        "sync_rounds": rounds,
        "async_merges": st["merges"],
        "sync_acc_at_budget": _acc_at(sync_curve, budget),
        "async_acc_at_budget": _acc_at(async_curve, budget),
        "mean_staleness": float(np.mean(stale)) if stale else 0.0,
        "sync_curve": sync_curve,
        "async_curve": async_curve,
        "traffic_ok": (sync_recon["bad"] == 0 and async_recon["bad"] == 0
                       and sync_recon["ok"] > 0 and async_recon["ok"] > 0),
        "traffic_events": {"sync": sync_recon, "async": async_recon},
    }


def run(fast: bool = None, buffer: int = None,
        straggler: float = 8.0) -> List[Dict]:
    fast = (not FULL) if fast is None else fast
    if fast:
        n, k, rounds, n_samples = 24, 6, 6, 600
    else:
        n, k, rounds, n_samples = 64, 8, 30, 2000
    b = buffer or max(1, k // 3)
    return [run_one(s, n_clients=n, cohort=k, buffer=b, rounds=rounds,
                    n_samples=n_samples, straggler=straggler)
            for s in SCHEMES]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI scale: N=24, K=6, 6 sync rounds")
    ap.add_argument("--buffer", type=int, default=None,
                    help="async merge buffer B (default K//3)")
    ap.add_argument("--straggler", type=float, default=8.0,
                    help="slowest/fastest completion ratio")
    args = ap.parse_args(argv)
    rows = run(fast=args.fast or None, buffer=args.buffer,
               straggler=args.straggler)
    print("scheme,sync_rounds,async_merges,sync_clock_s,async_acc@budget,"
          "sync_acc@budget,mean_staleness,traffic_ok")
    for r in rows:
        print(f"{r['scheme']},{r['sync_rounds']},{r['async_merges']},"
              f"{r['sync_clock_s']:.1f},{r['async_acc_at_budget']:.3f},"
              f"{r['sync_acc_at_budget']:.3f},{r['mean_staleness']:.2f},"
              f"{r['traffic_ok']}")
    n_bad = sum(not r["traffic_ok"] for r in rows)
    obs.log(f"# async engine merged {sum(r['async_merges'] for r in rows)} "
            f"buffers across {len(rows)} schemes within the sync budget; "
            f"traffic reconciliation "
            f"{'EXACT on both loops' if not n_bad else f'{n_bad} FAILURES'}")
    if n_bad:
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    main()
