"""Fig. 10 (extension) — closed-loop dynamic splitting, end to end.

The paper's fig. 6 compares resource strategies on MODEL latency/cost
only; this benchmark finally runs them through REAL training: each
strategy's cut schedule drives ``core.closed_loop.run_closed_loop`` —
live cut migration in ``FedSimulator`` (priced by
``sysmodel.traffic.migration_bits``), per-round wall-clock from the
P2.1-solved allocation (or the equal-split baseline), accuracy measured
on held-out data against CUMULATIVE wall-clock.

Regime: the paper's §V-A constants make latency COMPUTE-bound (0.1 GHz
client CPU dwarfs every comm term), where neither the allocation nor the
cut moves wall-clock. Fig. 10 therefore runs the comm-bound corner of
fig. 8 — 1 MHz total uplink band, 1 GHz edge-accelerator clients — where
X(v) and the bandwidth split dominate the round and dynamic splitting
has something to win.

Strategies (same data, same fading seed; baselines at v=1, the
shallowest/privacy-safest split):

* ``dynamic_ddqn``     — Algorithm 1's policy queried on the live channel
* ``fixed_cut_v1``     — constant cut, optimal allocation
* ``random_cut``       — uniform cut per round, optimal allocation
* ``fixed_alloc_v1``   — constant cut, equal-split resources (no P2.1)

Headline: at the wall-clock budget where the dynamic run finishes, the
fixed-alloc baseline is still mid-training — acc@budget(dynamic) >
acc@budget(fixed_alloc) — and the dynamic schedule actually moves the
cut (migration traffic is included in its reported bits).
"""
from __future__ import annotations

import argparse

from benchmarks.common import FULL, fed_setup
from repro.ccc.env import CuttingPointEnv, cnn_env_config
from repro.ccc.strategy import run_algorithm1
from repro.configs.paper_cnn import LIGHT_CONFIG
from repro.core.closed_loop import CutSchedule, run_closed_loop
from repro.core.simulator import FedSimulator, SimConfig

from repro import obs
from repro.sysmodel.comm import CommParams
from repro.sysmodel.comp import CompParams

BASELINE_CUT = 1
COMM = CommParams(total_bandwidth=1e6)     # below fig. 8's 5 MHz low end
COMP = CompParams(client_cpu_max=1e9)      # edge accelerator, not 0.1 GHz


def _sim(n_clients, batch, rho, seed, cut: int = BASELINE_CUT):
    return FedSimulator(LIGHT_CONFIG,
                        SimConfig(scheme="sfl_ga", cut=cut,
                                  n_clients=n_clients, batch=batch),
                        rho=rho, seed=seed)


def _env(n_clients, batch, seed):
    return CuttingPointEnv(cnn_env_config(n_clients=n_clients, batch=batch,
                                          seed=seed), comm=COMM, comp=COMP)


def run(rounds: int = None, episodes: int = None, dataset: str = "mnist",
        n_clients: int = 10, batch: int = 16, seed: int = 0,
        eval_every: int = 10):
    rounds = rounds or (120 if FULL else 60)
    episodes = episodes or (200 if FULL else 40)
    train, test, parts, rho = fed_setup(dataset, n_clients=n_clients,
                                        seed=seed)

    # Algorithm 1: learn the cut policy on the channel MDP first (cheap,
    # no training data involved), then EXECUTE it against live training.
    res = run_algorithm1(_env(n_clients, batch, seed), episodes=episodes)

    def loop(schedule, alloc="opt", name=None):
        return run_closed_loop(
            _sim(n_clients, batch, rho, seed), _env(n_clients, batch, seed),
            schedule, train, test, parts, rounds=rounds, alloc=alloc,
            eval_every=eval_every, batch_seed=seed, name=name)

    dyn = loop(res.cut_schedule(_env(n_clients, batch, seed)),
               name="dynamic_ddqn")
    fixed = loop(CutSchedule.constant(BASELINE_CUT),
                 name=f"fixed_cut_v{BASELINE_CUT}")
    rand = loop(CutSchedule.random(_env(n_clients, batch, seed), rounds,
                                   seed=seed), name="random_cut")
    fixed_alloc = loop(CutSchedule.constant(BASELINE_CUT), alloc="fixed",
                       name=f"fixed_alloc_v{BASELINE_CUT}")

    budget = dyn.total_latency_s  # acc@the dynamic run's finishing time
    rows = []
    for r in (dyn, fixed, rand, fixed_alloc):
        rows.append({
            "strategy": r.name, "final_acc": r.final_acc,
            "wall_clock_s": r.total_latency_s,
            "acc_at_budget": r.acc_at_time(budget),
            "total_mb": r.total_bits / 8e6,
            "migration_mb": r.migration_bits_total / 8e6,
            "n_migrations": r.n_migrations, "cuts": r.cuts,
            "curve": r.curve})
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--dataset", default="mnist")
    args = ap.parse_args()
    rows = run(rounds=args.rounds, episodes=args.episodes,
               dataset=args.dataset)
    budget = rows[0]["wall_clock_s"]
    obs.log(f"# fig10 closed-loop dynamic splitting "
          f"(sfl_ga, acc@budget={budget:.1f}s)")
    for r in rows:
        cuts = r["cuts"]
        cut_str = ",".join(map(str, cuts[:12])) + ("..." if len(cuts) > 12
                                                   else "")
        obs.log(f"  {r['strategy']:>15}: acc@budget={r['acc_at_budget']:.3f} "
              f"final_acc={r['final_acc']:.3f} wall={r['wall_clock_s']:.1f}s "
              f"traffic={r['total_mb']:.1f}MB "
              f"(migrated {r['migration_mb']:.1f}MB in "
              f"{r['n_migrations']} moves) cuts=[{cut_str}]")
    dyn, fx_alloc = rows[0], rows[3]
    verdict = dyn["acc_at_budget"] > fx_alloc["acc_at_budget"]
    obs.log(f"  dynamic beats fixed-alloc at its own budget: {verdict} "
          f"({dyn['acc_at_budget']:.3f} vs {fx_alloc['acc_at_budget']:.3f})")


if __name__ == "__main__":
    main()
