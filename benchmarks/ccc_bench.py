"""CCC throughput: the batched device-resident path vs the numpy loop.

Times three levels of the stack (CSV ``name,us_per_call,derived``):

* ``p21_solve``        — scalar numpy ``solve_p21`` per round.
* ``p21_solve_batched``— jitted ``solve_p21_batched`` per round (B at once).
* ``env_step``         — scalar ``CuttingPointEnv.step`` (reward incl. solve).
* ``env_step_batched`` — jitted ``BatchedCuttingPointEnv.step`` per env-step.
* ``fused_train_step`` — the full act+observe+update fused DDQN step.

The acceptance bar (ISSUE 3): batched reward evaluation at B=64 must be
≥ 10× faster per env-step than the numpy loop on CPU.

Run:  PYTHONPATH=src:. python benchmarks/ccc_bench.py [--quick] [--n-envs 64]
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _timeit(fn, iters: int, warmup: int = 1) -> float:
    """Seconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(iters):
        fn()
    return (time.time() - t0) / iters


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing iterations (CI smoke)")
    ap.add_argument("--n-envs", type=int, default=64)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.ccc.convex import solve_p21
    from repro.ccc.convex_jax import solve_p21_batched
    from repro.ccc.ddqn import BatchedDDQNAgent, DDQNConfig
    from repro.ccc.env import (BatchedCuttingPointEnv, CuttingPointEnv,
                               cnn_env_config)
    from repro.sysmodel.comm import CommParams, path_loss_gain
    from repro.sysmodel.comp import CompParams

    B = args.n_envs
    iters = 3 if args.quick else 10
    np_iters = 4 if args.quick else 16

    # ---- P2.1 solver ------------------------------------------------
    rng = np.random.RandomState(0)
    N = 10
    gains = np.stack([path_loss_gain(rng.uniform(0.05, 0.5, N), rng)
                      for _ in range(B)])
    X = rng.uniform(1e5, 5e7, B)
    comm, comp = CommParams(), CompParams()

    t_np = _timeit(lambda: solve_p21(gains[0], X[0], 16, comm, comp),
                   np_iters)
    print(f"p21_solve,{t_np*1e6:.0f},numpy per-round")

    solve = jax.jit(lambda g, x: solve_p21_batched(g, x, 16.0, comm, comp))
    gj = jnp.asarray(gains, jnp.float32)
    xj = jnp.asarray(X, jnp.float32)
    t_j = _timeit(lambda: jax.block_until_ready(solve(gj, xj).chi), iters)
    print(f"p21_solve_batched,{t_j/B*1e6:.0f},B={B} jitted per-round "
          f"speedup={t_np/(t_j/B):.1f}x")

    # ---- env reward step (solve + tables + fading redraw) ----------
    cfg = cnn_env_config(horizon=10, batch=16, epsilon=0.001, seed=0)
    senv = CuttingPointEnv(cfg)
    senv.reset()
    arng = np.random.RandomState(1)

    def np_step():
        senv.step(int(arng.randint(senv.n_actions)))

    t_np_env = _timeit(np_step, np_iters)
    print(f"env_step,{t_np_env*1e6:.0f},numpy per-env-step")

    benv = BatchedCuttingPointEnv(cfg, n_envs=B)
    state, obs = benv.reset()
    actions = jnp.asarray(arng.randint(0, benv.n_actions, B), jnp.int32)
    bstep = jax.jit(benv.step)

    def jax_step():
        s2, o, r, d, i = bstep(state, actions)
        jax.block_until_ready(r)

    t_j_env = _timeit(jax_step, iters)
    env_speedup = t_np_env / (t_j_env / B)
    print(f"env_step_batched,{t_j_env/B*1e6:.0f},B={B} jitted per-env-step "
          f"speedup={env_speedup:.1f}x")

    # ---- fused DDQN train step (act+env+replay+update+sync) --------
    agent = BatchedDDQNAgent(DDQNConfig(state_dim=benv.state_dim,
                                        n_actions=benv.n_actions, seed=0))
    st, ob = benv.reset()
    holder = {"st": st, "ob": ob}

    def fused():
        holder["st"], holder["ob"], r, *_ = agent.fused_step(
            benv, holder["st"], holder["ob"])
        jax.block_until_ready(r)

    t_fused = _timeit(fused, iters)
    print(f"fused_train_step,{t_fused/B*1e6:.0f},B={B} act+observe+update "
          f"per-env-step")

    ok = env_speedup >= 10.0
    print(f"# batched-vs-numpy env-step speedup {env_speedup:.1f}x "
          f"(target >=10x): {'OK' if ok else 'BELOW TARGET'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
