"""Kernel micro-benchmarks (CPU timings are indicative only — the Pallas
kernels run in interpret mode here; the ref path is the jnp oracle)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import obs


def _time(fn, *args, iters=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def run():
    from repro.kernels import ops

    rows = []
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 256, 2, 64), jnp.float32)
    rows.append(("flash_attention_ref_256", _time(
        jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, backend="jnp")),
        q, k, v)))

    x = jax.random.normal(k1, (1, 256, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(k2, (1, 256, 4))) * 0.1
    A = -jnp.exp(jax.random.normal(k3, (4,)) * 0.5)
    B = jax.random.normal(k1, (1, 256, 1, 64)) * 0.3
    C = jax.random.normal(k2, (1, 256, 1, 64)) * 0.3
    rows.append(("ssd_ref_256", _time(
        jax.jit(lambda *a: ops.ssd(*a, chunk=64, backend="jnp")[0]),
        x, dt, A, B, C)))

    g = jax.random.normal(k1, (8, 1024, 512))
    rho = jnp.full((8,), 0.125)
    rows.append(("grad_agg_ref_8x1024x512", _time(
        jax.jit(lambda a, b: ops.grad_agg(a, b, backend="jnp")), g, rho)))

    # cut-layer codec kernels (jnp oracle backend; the Pallas kernels run
    # the same math fused on TPU)
    for bits in (8, 4):
        rows.append((f"quantize_int{bits}_ref_8x1024x512", _time(
            jax.jit(lambda a, b=bits: ops.quantize(a, seed=0, bits=b,
                                                   backend="jnp")), g)))
        q, s = ops.quantize(g, seed=0, bits=bits, backend="jnp")
        rows.append((f"dequant_agg_int{bits}_ref_8x1024x512", _time(
            jax.jit(lambda a, b, c, bb=bits: ops.dequant_agg(
                a, b, c, bits=bb, backend="jnp")), q, s, rho)))
    return rows


def main():
    for name, us in run():
        obs.log(f"  {name}: {us:.0f} us/call")


if __name__ == "__main__":
    main()
