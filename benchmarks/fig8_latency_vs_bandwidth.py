"""Fig. 8 — per-round latency vs total bandwidth, per scheme.

Paper claim: all schemes speed up with bandwidth; SFL-GA is lowest
(broadcast downlink + no model aggregation); SFL slightly above PSL
(client-model aggregation traffic); FL worst (full-model exchange +
on-device training).
"""
from __future__ import annotations

import numpy as np

from repro.ccc.convex import solve_p21
from repro.configs.paper_cnn import LIGHT_CONFIG
from repro.models import cnn
from repro.sysmodel.comm import CommParams, downlink_rate, path_loss_gain, uplink_rate
from repro.sysmodel.comp import CompParams

from repro import obs

BANDWIDTHS = (5e6, 10e6, 20e6, 40e6)


def _lat(scheme: str, comm: CommParams, gains, cut=2, batch=16) -> float:
    comp = CompParams()
    N = len(gains)
    if scheme == "fl":
        w = (comp.client_fwd_flops + comp.client_bwd_flops
             + comp.server_fwd_flops + comp.server_bwd_flops)
        t_comp = batch * w / comp.client_cpu_max
        q_bits = cnn.total_params(LIGHT_CONFIG) * 32
        bw = np.full(N, comm.total_bandwidth / N)
        r_up = uplink_rate(bw, np.full(N, comm.client_power), gains, comm)
        return t_comp + float(np.max(q_bits / r_up)) \
            + float(np.max(q_bits / downlink_rate(gains, comm)))
    X_bits = cnn.smashed_numel(LIGHT_CONFIG, cut) * batch * 32
    r = solve_p21(gains, X_bits, batch, comm, comp)
    lat = r.total
    if scheme == "psl":
        # unicast downlink instead of single broadcast: N gradient payloads
        # share the band — approximate as N sequential broadcasts
        r_dn = downlink_rate(gains, comm)
        lat += (N - 1) * float(np.max(X_bits / r_dn))
    if scheme == "sfl":
        r_dn = downlink_rate(gains, comm)
        lat += (N - 1) * float(np.max(X_bits / r_dn))
        phi_bits = cnn.phi(LIGHT_CONFIG, cut) * 32
        bw = np.full(N, comm.total_bandwidth / N)
        r_up = uplink_rate(bw, np.full(N, comm.client_power), gains, comm)
        lat += float(np.max(phi_bits / r_up)) \
            + float(np.max(phi_bits / downlink_rate(gains, comm)))
    return lat


def run():
    rng = np.random.RandomState(0)
    gains = path_loss_gain(rng.uniform(0.05, 0.5, 10), rng)
    rows = []
    for bw in BANDWIDTHS:
        comm = CommParams(total_bandwidth=bw)
        rows.append({"bandwidth_mhz": bw / 1e6,
                     **{s: _lat(s, comm, gains)
                        for s in ("sfl_ga", "psl", "sfl", "fl")}})
    return rows


def main():
    obs.log("# fig8 latency (s/round) vs bandwidth (MHz)")
    obs.log("  MHz, sfl_ga, psl, sfl, fl")
    for row in run():
        obs.log(f"  {row['bandwidth_mhz']:.0f}, {row['sfl_ga']:.3f}, "
              f"{row['psl']:.3f}, {row['sfl']:.3f}, {row['fl']:.3f}")


if __name__ == "__main__":
    main()
