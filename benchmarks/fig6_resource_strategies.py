"""Fig. 6 — latency under different resource strategies.

Compares Algorithm 1 (DDQN cut + convex allocation) against:
fixed-cut + optimal allocation, fixed-cut + fixed (equal-split) allocation,
and random-cut + optimal allocation. Metric: cumulative latency + weighted
cost over a horizon.

``--backend jax`` trains Algorithm 1 on the batched device-resident path
(B envs per fused step, DESIGN.md §11); the learned policy is then
evaluated on the same scalar numpy env as every baseline, so the rows
stay directly comparable across backends.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import FULL
from repro.ccc.env import (BatchedCuttingPointEnv, CuttingPointEnv,
                           cnn_env_config)
from repro.ccc.strategy import (fixed_alloc_policy_cost, fixed_cut_policy_cost,
                                random_cut_policy_cost, run_algorithm1,
                                run_algorithm1_batched)

from repro import obs


def run(episodes: int = None, horizon: int = 10, backend: str = "numpy",
        n_envs: int = 32):
    episodes = episodes or (200 if FULL else 60)
    kw = dict(horizon=horizon, batch=16, epsilon=0.001)
    mk = lambda seed: CuttingPointEnv(cnn_env_config(seed=seed, **kw))
    if backend == "jax":
        benv = BatchedCuttingPointEnv(cnn_env_config(seed=7, **kw),
                                      n_envs=min(n_envs, episodes))
        res = run_algorithm1_batched(benv, episodes=episodes)
        act = lambda s: int(res.agent.act(s)[0])
    else:
        res = run_algorithm1(mk(7), episodes=episodes)
        act = lambda s: res.agent.act(s, greedy=True)

    env = mk(7)
    s = env.reset()
    alg1_lat, alg1_cost, done = 0.0, 0.0, False
    while not done:
        a = act(s)
        s, r, done, info = env.step(a)
        alg1_lat += info["latency"]
        alg1_cost += -r
    rows = [{"strategy": f"algorithm1(ddqn+convex,{backend})",
             "latency": alg1_lat, "cost": alg1_cost,
             "policy": res.greedy_policy}]
    for v in (1, 2):
        f = fixed_cut_policy_cost(mk(7), v, rounds=horizon)
        rows.append({"strategy": f"fixed_cut_v{v}_opt_alloc", **f})
        g = fixed_alloc_policy_cost(mk(7), v, rounds=horizon)
        rows.append({"strategy": f"fixed_cut_v{v}_fixed_alloc", **g})
    rows.append({"strategy": "random_cut_opt_alloc",
                 **random_cut_policy_cost(mk(7), rounds=horizon)})
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy")
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--n-envs", type=int, default=32)
    args = ap.parse_args()
    obs.log(f"# fig6 resource strategies (10-round horizon, {args.backend})")
    for row in run(episodes=args.episodes, backend=args.backend,
                   n_envs=args.n_envs):
        extra = f" policy={row['policy']}" if "policy" in row else ""
        obs.log(f"  {row['strategy']}: latency={row['latency']:.2f}s "
              f"cost={row['cost']:.2f}{extra}")


if __name__ == "__main__":
    main()
