"""Fig. 6 — latency under different resource strategies.

Compares Algorithm 1 (DDQN cut + convex allocation) against:
fixed-cut + optimal allocation, fixed-cut + fixed (equal-split) allocation,
and random-cut + optimal allocation. Metric: cumulative latency + weighted
cost over a horizon.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL
from repro.ccc.env import CuttingPointEnv, cnn_env_config
from repro.ccc.strategy import (fixed_alloc_policy_cost, fixed_cut_policy_cost,
                                random_cut_policy_cost, run_algorithm1)


def run(episodes: int = None, horizon: int = 10):
    episodes = episodes or (200 if FULL else 60)
    mk = lambda seed: CuttingPointEnv(cnn_env_config(
        horizon=horizon, batch=16, epsilon=0.001, seed=seed))
    res = run_algorithm1(mk(7), episodes=episodes)

    env = mk(7)
    s = env.reset()
    alg1_lat, alg1_cost, done = 0.0, 0.0, False
    while not done:
        a = res.agent.act(s, greedy=True)
        s, r, done, info = env.step(a)
        alg1_lat += info["latency"]
        alg1_cost += -r
    rows = [{"strategy": "algorithm1(ddqn+convex)", "latency": alg1_lat,
             "cost": alg1_cost, "policy": res.greedy_policy}]
    for v in (1, 2):
        f = fixed_cut_policy_cost(mk(7), v, rounds=horizon)
        rows.append({"strategy": f"fixed_cut_v{v}_opt_alloc", **f})
        g = fixed_alloc_policy_cost(mk(7), v, rounds=horizon)
        rows.append({"strategy": f"fixed_cut_v{v}_fixed_alloc", **g})
    rows.append({"strategy": "random_cut_opt_alloc",
                 **random_cut_policy_cost(mk(7), rounds=horizon)})
    return rows


def main():
    print("# fig6 resource strategies (10-round horizon)")
    for row in run():
        extra = f" policy={row['policy']}" if "policy" in row else ""
        print(f"  {row['strategy']}: latency={row['latency']:.2f}s "
              f"cost={row['cost']:.2f}{extra}")


if __name__ == "__main__":
    main()
