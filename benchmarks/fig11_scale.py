"""Fig. 11 (extension) — scaling past N=10: round cost vs bank size.

The cohort engine (DESIGN.md §13) keeps ONE aggregated server model
between rounds and trains a sampled cohort of K participants per round,
so both server memory and round wall-clock should be INDEPENDENT of how
many clients are registered in the bank. This benchmark sweeps
N ∈ {10, 100, 1k, 10k} at fixed K and measures:

* per-round wall-clock (post-jit; gather → vmapped round → scatter),
  compared against the N=K full-participation baseline — the acceptance
  bar is within 2× of it at N=10k on a 2-core CPU;
* server-side state bytes — ONE copy, flat across the sweep (the
  pre-cohort layout held N replicas, O(N));
* client-bank bytes — the only O(N) state left, client-side params only;
* the ``replacement_fraction`` stat surfaced by ``data.federated``:
  at N=10k a 2k-sample dataset leaves every client < batch samples, the
  exact silent-data-repetition condition the stat exists to expose.

Run:  PYTHONPATH=src:. python benchmarks/fig11_scale.py [--fast]
Fast mode (CI) sweeps {10, 256} at K=8 with 2 timed rounds.
"""
from __future__ import annotations

import argparse
import time
import warnings
from typing import Dict, List

import numpy as np

from benchmarks.common import FULL
from repro import obs

CUT = 1  # keep the O(N) bank small (conv1 only) — the sweep is about N
BATCH = 16


def _bytes(tree) -> int:
    import jax

    return sum(int(np.asarray(x).nbytes) for x in jax.tree.leaves(tree))


def run_one(n_clients: int, cohort: int, rounds: int, n_samples: int,
            seed: int = 0) -> Dict:
    from repro.configs.paper_cnn import LIGHT_CONFIG
    from repro.core.simulator import FedSimulator, SimConfig
    from repro.data import iid_partition, make_image_dataset
    from repro.data.federated import (replacement_fraction, rho_weights,
                                      round_batches)

    ds = make_image_dataset("mnist", n=n_samples, seed=seed)
    parts = iid_partition(len(ds.x), n_clients, seed=seed)
    full = cohort >= n_clients
    sim = FedSimulator(
        LIGHT_CONFIG,
        SimConfig(scheme="sfl_ga", cut=CUT, n_clients=n_clients, batch=BATCH,
                  cohort=None if full else cohort,
                  sampler="full" if full else "uniform", cohort_seed=seed),
        rho=rho_weights(parts), seed=seed)
    rng = np.random.RandomState(seed)

    def one_round():
        idx, _ = sim.cohort_for_round(sim._t)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # replacement reported as a stat
            xs, ys = round_batches(ds, parts, BATCH, 1, rng, idx=idx)
        return sim.run_round(xs, ys)

    one_round()  # jit warmup
    times = []
    loss = float("nan")
    for _ in range(rounds):
        t0 = time.perf_counter()
        m = one_round()
        times.append(time.perf_counter() - t0)
        loss = m["loss"]
    return {
        "n_clients": n_clients,
        "cohort": sim.n_participants,
        "round_ms": 1e3 * float(np.median(times)),
        "server_bytes": _bytes(sim.state["server"]),
        "bank_bytes": _bytes(sim.state["client"]),
        "replacement_fraction": replacement_fraction(parts, BATCH),
        "loss": loss,
    }


def run(fast: bool = None) -> List[Dict]:
    fast = (not FULL) if fast is None else fast
    if fast:
        ns, k, rounds = [10, 256], 8, 2
    else:
        ns, k, rounds = [10, 100, 1000, 10000], 16, 3

    def samples_for(n):  # every client needs >= 1 sample; 2/client at 10k
        return max(2000, 2 * n)

    rows = [run_one(k, k, rounds, samples_for(k))]  # N=K baseline
    rows[0]["name"] = "baseline_N=K"
    for n in ns:
        r = run_one(n, k, rounds, samples_for(n))
        r["name"] = f"N={n}"
        rows.append(r)
    base = rows[0]
    for r in rows:
        r["round_ms_vs_baseline"] = r["round_ms"] / base["round_ms"]
        r["server_bytes_flat"] = r["server_bytes"] == base["server_bytes"]
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI sweep: N in {10, 256}, K=8, 2 timed rounds")
    args = ap.parse_args(argv)
    rows = run(fast=args.fast or None)
    print("name,n_clients,cohort,round_ms,server_bytes,bank_bytes,"
          "ratio_vs_baseline,replacement_fraction")
    for r in rows:
        print(f"{r['name']},{r['n_clients']},{r['cohort']},"
              f"{r['round_ms']:.1f},{r['server_bytes']},{r['bank_bytes']},"
              f"{r['round_ms_vs_baseline']:.2f},"
              f"{r['replacement_fraction']:.2f}")
    worst = max(r["round_ms_vs_baseline"] for r in rows[1:])
    flat = all(r["server_bytes_flat"] for r in rows)
    obs.log(f"# server state one copy across the sweep: {flat}; "
            f"worst round-time ratio vs N=K baseline: {worst:.2f}x "
            f"(bar: <= 2x)")
    return rows


if __name__ == "__main__":
    main()
