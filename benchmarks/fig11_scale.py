"""Fig. 11 (extension) — scaling past N=10: round cost vs bank size.

The cohort engine (DESIGN.md §13) keeps ONE aggregated server model
between rounds and trains a sampled cohort of K participants per round,
so both server memory and round wall-clock should be INDEPENDENT of how
many clients are registered in the bank. The bank backends (DESIGN.md
§15) take the last O(N) state off the device: ``--bank host`` keeps the
client bank in host memory and double-buffers the per-round K-slice
copies behind training, so DEVICE memory for client state is O(K) — the
wall between N=10k and N=1M. This benchmark sweeps N at fixed K and
measures:

* per-round wall-clock (post-jit; gather → vmapped round → scatter),
  compared against the N=K full-participation baseline — the acceptance
  bar is within 2× of it at N=10k on a 2-core CPU;
* server-side state bytes — ONE copy, flat across the sweep;
* client-bank bytes — the only O(N) state left — plus, from
  ``repro.obs``/``ClientBank.stats()``, the PEAK device-resident
  client-state bytes (``--bank host`` bar: ≤ 2× the K-slice — the
  staged next-round slice plus the in-flight one) and the prefetch
  hit rate / gather-wait that show the overlap working;
* the ``replacement_fraction`` stat surfaced by ``data.federated``.

N ≥ 100k rows use ``data.federated.CyclicPartition`` (O(1)-memory
partition view) — ``iid_partition`` would build a million index arrays
before the first round.

Run:  PYTHONPATH=src:. python benchmarks/fig11_scale.py [--fast]
          [--bank device|host|sharded] [--no-prefetch]
Fast mode (CI) sweeps {10, 256} at K=8 with 2 timed rounds.
``--bank host`` adds N=100k and N=1M rows to the full sweep.
``--smoke`` is the CI scale gate: N=100k, K=16, host bank, exits
non-zero if the obs-reported peak device client-state bytes exceed the
2× K-slice budget.
"""
from __future__ import annotations

import argparse
import sys
import time
import warnings
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import FULL
from repro import obs

CUT = 1  # keep the O(N) bank small (conv1 only) — the sweep is about N
BATCH = 16
# CyclicPartition threshold: above this, skip materialized partitions
HUGE_N = 100_000


def _bytes(tree) -> int:
    import jax

    return sum(int(np.asarray(x).nbytes) for x in jax.tree.leaves(tree))


def run_one(n_clients: int, cohort: int, rounds: int, n_samples: int,
            seed: int = 0, bank: str = "device",
            prefetch: bool = True) -> Dict:
    from repro.configs.paper_cnn import LIGHT_CONFIG
    from repro.core.simulator import FedSimulator, SimConfig
    from repro.data import iid_partition, make_image_dataset
    from repro.data.federated import (CyclicPartition, replacement_fraction,
                                      rho_weights, round_batches)

    huge = n_clients >= HUGE_N
    ds = make_image_dataset("mnist", n=min(n_samples, 4096) if huge
                            else n_samples, seed=seed)
    if huge:  # lazy partition view + uniform ρ: no O(N) host lists
        parts = CyclicPartition(len(ds.x), n_clients)
        rho = None
    else:
        parts = iid_partition(len(ds.x), n_clients, seed=seed)
        rho = rho_weights(parts)
    full = cohort >= n_clients
    sim = FedSimulator(
        LIGHT_CONFIG,
        SimConfig(scheme="sfl_ga", cut=CUT, n_clients=n_clients, batch=BATCH,
                  cohort=None if full else cohort,
                  sampler="full" if full else "uniform", cohort_seed=seed,
                  bank=bank, bank_prefetch=prefetch),
        rho=rho, seed=seed)
    rng = np.random.RandomState(seed)

    def one_round():
        idx, _ = sim.cohort_for_round(sim._t)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # replacement reported as a stat
            xs, ys = round_batches(ds, parts, BATCH, 1, rng, idx=idx)
        return sim.run_round(xs, ys)

    one_round()  # jit warmup
    times = []
    loss = float("nan")
    for _ in range(rounds):
        t0 = time.perf_counter()
        m = one_round()
        times.append(time.perf_counter() - t0)
        loss = m["loss"]
    sim.close()  # drain the async pipeline + release the bank worker
    st = sim.bank.stats()
    return {
        "n_clients": n_clients,
        "cohort": sim.n_participants,
        "round_ms": 1e3 * float(np.median(times)),
        "server_bytes": _bytes(sim.state["server"]),
        "bank_bytes": st["bank_bytes"],
        "bank": st["backend"],
        "device_bytes_peak": st["device_bytes_peak"],
        "prefetch_hits": st["prefetch_hits"],
        "prefetch_misses": st["prefetch_misses"],
        "gather_wait_ms": 1e3 * st["gather_wait_s"],
        "replacement_fraction": replacement_fraction(parts, BATCH),
        "loss": loss,
    }


def run(fast: bool = None, bank: str = "device",
        prefetch: bool = True) -> List[Dict]:
    fast = (not FULL) if fast is None else fast
    if fast:
        ns, k, rounds = [10, 256], 8, 2
    else:
        ns, k, rounds = [10, 100, 1000, 10000], 16, 3
        if bank != "device":
            # the rows the off-device bank exists for: past the ~830 MB
            # device wall a stacked N=1M bank would hit
            ns = ns + [100_000, 1_000_000]

    def samples_for(n):  # every client needs >= 1 sample; 2/client at 10k
        return max(2000, 2 * n)

    rows = [run_one(k, k, rounds, samples_for(k), bank=bank,
                    prefetch=prefetch)]  # N=K baseline
    rows[0]["name"] = "baseline_N=K"
    for n in ns:
        r = run_one(n, k, rounds, samples_for(n), bank=bank,
                    prefetch=prefetch)
        r["name"] = f"N={n}"
        rows.append(r)
    base = rows[0]
    for r in rows:
        r["round_ms_vs_baseline"] = r["round_ms"] / base["round_ms"]
        r["server_bytes_flat"] = r["server_bytes"] == base["server_bytes"]
    return rows


def run_smoke(n_clients: int = 100_000, cohort: int = 16,
              rounds: int = 4) -> Dict:
    """CI scale gate: a host-bank run at N=100k whose obs-reported peak
    device client-state bytes must stay within the O(K) budget (2× the
    K-slice: one in-flight slice + one staged prefetch)."""
    rec = obs.Recorder()  # in-memory events; the gate reads the stream
    with obs.use_recorder(rec):
        row = run_one(n_clients, cohort, rounds, 4096, bank="host")
    peaks = [e["bank"]["device_bytes_peak"] for e in rec.events
             if e.get("kind") == "round" and e.get("name") == "round"]
    assert peaks, "no round events recorded — obs wiring broken"
    peak = max(peaks)
    slice_bytes = row["bank_bytes"] // n_clients * cohort
    budget = 2 * slice_bytes
    stacked_mb = row["bank_bytes"] / 1e6
    row.update(device_bytes_peak=peak, slice_bytes=slice_bytes,
               budget_bytes=budget, ok=peak <= budget)
    obs.log(f"# scale smoke: N={n_clients} K={cohort} bank=host — peak "
            f"device client-state {peak} B vs budget {budget} B "
            f"(2x K-slice; stacked bank would be {stacked_mb:.0f} MB "
            f"device-resident); prefetch {row['prefetch_hits']} hits / "
            f"{row['prefetch_misses']} misses -> "
            f"{'OK' if row['ok'] else 'OVER BUDGET'}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI sweep: N in {10, 256}, K=8, 2 timed rounds")
    ap.add_argument("--bank", default="device",
                    choices=["device", "host", "sharded"],
                    help="client-bank backend (core.bank); 'host' adds "
                         "N=100k and N=1M rows to the full sweep")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the host bank's double-buffered "
                         "prefetch (measures the overlap win)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale gate: N=100k host-bank run; exit "
                         "non-zero if peak device client-state bytes "
                         "exceed 2x the K-slice budget")
    args = ap.parse_args(argv)
    if args.smoke:
        row = run_smoke()
        sys.exit(0 if row["ok"] else 1)
    rows = run(fast=args.fast or None, bank=args.bank,
               prefetch=not args.no_prefetch)
    print("name,n_clients,cohort,round_ms,server_bytes,bank_bytes,"
          "device_peak_bytes,prefetch_hit_miss,ratio_vs_baseline,"
          "replacement_fraction")
    for r in rows:
        print(f"{r['name']},{r['n_clients']},{r['cohort']},"
              f"{r['round_ms']:.1f},{r['server_bytes']},{r['bank_bytes']},"
              f"{r['device_bytes_peak']},"
              f"{r['prefetch_hits']}/{r['prefetch_misses']},"
              f"{r['round_ms_vs_baseline']:.2f},"
              f"{r['replacement_fraction']:.2f}")
    worst = max(r["round_ms_vs_baseline"] for r in rows[1:])
    flat = all(r["server_bytes_flat"] for r in rows)
    obs.log(f"# server state one copy across the sweep: {flat}; "
            f"worst round-time ratio vs N=K baseline: {worst:.2f}x "
            f"(bar: <= 2x); bank={args.bank}")
    return rows


if __name__ == "__main__":
    main()
